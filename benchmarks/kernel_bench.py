"""Kernel micro-benchmark: exactness sweep + fused-vs-unfused pipeline A/B
+ roofline-fraction gate + end-to-end quantized-vs-fp32 decode-step A/B.

Sections:

1. **Exactness sweep** — for each kernel (int8 GEMM, packed int4/int2 GEMM,
   thermometer-decomposed temporal GEMM, fused pipeline at per-tensor AND
   per-token activation scales) checks bit-exactness of the Pallas body
   (interpret mode) and the XLA path against the jnp oracle, then times the
   XLA path (what CPU users run; TPU would run the compiled Pallas kernels,
   which cannot be timed here).
2. **Pipeline A/B** — times the complete dynamic-quant linear layer through
   qlinear.gemm with ``fused=True`` vs ``fused=False`` on the XLA path and
   counts device dispatches for both (DESIGN.md §4's ≥6 → 2 claim, measured).
3. **Roofline gate** — compiles the two serving hot-path kernels (fused
   per-token tuGEMM, paged flash-decode attention — on CPU the XLA twins
   those paths actually run), prices their optimized-HLO byte traffic under
   the running backend's HW profile, and reports achieved fraction of the
   memory-bound roofline. Below-floor fractions **hard-fail on accelerator
   backends** (tpu/gpu) and are report-only on CPU (DESIGN.md §13).
4. **E2E decode A/B** — a full continuous-batching decode step on the smoke
   model: fp32 vs surgered int8/int4 (dynamic + prequant), logits
   correlation vs fp32, plus the per-step tuGEMM cycle totals and modeled
   energy from the stats-enabled path (DESIGN.md §6).
5. **Mixed-policy A/B** — uniform int8 vs the mixed QuantPolicy deployment
   (attn int8 / mlp int2 / rest bf16, DESIGN.md §7): per-bits cycle split
   and modeled energy on the same decode step.

``benchmarks/BENCH_kernels.json`` is a **per-backend keyed trajectory**
(schema 2): ``{"schema": 2, "backends": {backend: latest-entry},
"history": [compact per-emit rows with backend + git rev]}`` — so a CPU
refresh never clobbers the TPU numbers and a regression is visible the PR
it lands. v1 (flat single-snapshot) files migrate on first write.
``BENCH_e2e.json`` / ``BENCH_policy.json`` use the same store. ``--fast``
never writes the committed files but asserts the schema round-trips and
history appends in-memory. Usage: ``PYTHONPATH=src python
benchmarks/kernel_bench.py [--fast]``.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.ref import matmul_int_ref
from repro.quant import GemmBackend, effective_policy, gemm, tree_totals_by_bits

_OUT = pathlib.Path(__file__).resolve().parent / "BENCH_kernels.json"
_OUT_E2E = pathlib.Path(__file__).resolve().parent / "BENCH_e2e.json"
_OUT_POLICY = pathlib.Path(__file__).resolve().parent / "BENCH_policy.json"

SCHEMA = 2
_HISTORY_CAP = 100

# declared floors: achieved fraction of the memory-bound roofline each
# serving hot-path kernel must clear on an accelerator backend
ROOFLINE_FLOORS = {"tugemm_fused_pertoken": 0.3, "flash_paged_decode": 0.3}


def git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=pathlib.Path(__file__).resolve().parent,
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip()
    except Exception:
        return "unknown"


def _migrate(store: dict) -> dict:
    """v1 (flat single-backend snapshot) -> v2 per-backend keyed store."""
    if not isinstance(store, dict) or not store:
        return {"schema": SCHEMA, "backends": {}, "history": []}
    if store.get("schema") == SCHEMA:
        store.setdefault("backends", {})
        store.setdefault("history", [])
        return store
    return {"schema": SCHEMA,
            "backends": {store.get("backend", "cpu"): store},
            "history": []}


def merge_entry(store: dict, backend: str, entry: dict, rev: str) -> dict:
    """Set ``backends[backend]`` to the new entry and append a compact
    history row (trajectory: backend, git rev, exactness, headline numbers).
    Returns the migrated/updated store (mutated in place when already v2)."""
    store = _migrate(store)
    entry = dict(entry, git_rev=rev)
    store["backends"][backend] = entry
    row: dict = {"backend": backend, "git_rev": rev}
    if "exact" in entry:
        row["exact"] = entry["exact"]
    if entry.get("timings"):
        row["timings"] = entry["timings"]
    if entry.get("pipeline"):
        row["fused_speedup_min"] = min(
            r["speedup"] for r in entry["pipeline"].values())
    if entry.get("roofline"):
        row["roofline_fraction"] = {
            k: v["fraction"] for k, v in entry["roofline"].items()}
    store["history"].append(row)
    store["history"] = store["history"][-_HISTORY_CAP:]
    return store


def emit(path: pathlib.Path, backend: str, entry: dict) -> None:
    """Merge one bench emit into a per-backend store file on disk."""
    try:
        store = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError):
        store = {}
    store = merge_entry(store, backend, entry, git_rev())
    path.write_text(json.dumps(store, indent=2, sort_keys=True) + "\n")
    print(f"wrote {path} (backends: {sorted(store['backends'])}, "
          f"history: {len(store['history'])})")


def check_store_roundtrip(backend: str, entry: dict) -> None:
    """--fast invariant: the v2 schema JSON-round-trips, keys per backend,
    appends history, and migrates a v1 snapshot — all in memory."""
    s1 = merge_entry({}, backend, entry, "aaaaaaa")
    s1 = json.loads(json.dumps(s1))                    # round-trip
    s2 = merge_entry(s1, backend, entry, "bbbbbbb")
    assert s2["schema"] == SCHEMA and backend in s2["backends"]
    assert len(s2["history"]) == 2, s2["history"]
    assert s2["history"][-1]["git_rev"] == "bbbbbbb"
    other = merge_entry(s2, backend + "_other", entry, "ccccccc")
    assert set(other["backends"]) == {backend, backend + "_other"}
    v1 = {"backend": backend, "exact": True, "timings": {"t": 1.0}}
    m = merge_entry(v1, backend, entry, "ddddddd")
    assert m["schema"] == SCHEMA and len(m["history"]) == 1
    print("[schema] per-backend store round-trips, appends history, "
          "migrates v1: ok")


def _rand_int8(key, shape, bits=8):
    m = 1 << (bits - 1)
    return jax.random.randint(key, shape, -m, m, dtype=jnp.int32).astype(jnp.int8)


def _time(fn, *args, iters=5):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def bench_exactness(shapes, out):
    key = jax.random.PRNGKey(0)
    print(f"\n{'kernel':<18} {'shape':<18} {'xla ms':>8} {'exact(xla)':>11} {'exact(interp)':>14}")
    for (M, K, N) in shapes:
        ka, kb = jax.random.split(jax.random.fold_in(key, M * N))
        a = _rand_int8(ka, (M, K))
        b = _rand_int8(kb, (K, N))
        ref = matmul_int_ref(a, b)

        y_xla = ops.matmul_int8(a, b, impl="xla")
        ok_x = bool((y_xla == ref).all())
        ok_i = True
        if M <= 128:  # interpret mode is python-slow; keep it to small shapes
            y_int = ops.matmul_int8(a, b, impl="pallas_interpret")
            ok_i = bool((y_int == ref).all())
        dt = _time(lambda a, b: ops.matmul_int8(a, b, impl="xla"), a, b)
        out["exact"] &= ok_x and ok_i
        out["timings"][f"int8_{M}x{K}x{N}"] = dt * 1e3
        gmacs = M * K * N / dt / 1e9
        print(f"{'matmul_int8':<18} {f'{M}x{K}x{N}':<18} {dt*1e3:>8.2f} {str(ok_x):>11} {str(ok_i):>14}  ({gmacs:.1f} GMAC/s)")

        for bits in (4, 2):
            mb = 1 << (bits - 1)
            a_s = jnp.clip(a, -mb, mb - 1)
            b_s = jnp.clip(b, -mb, mb - 1)
            packed = ops.pack_weights(b_s, bits)
            y_p = ops.matmul_packed(a_s, packed, bits=bits, impl="xla")
            ref_p = matmul_int_ref(a_s, b_s)
            ok_p = bool((y_p == ref_p).all())
            out["exact"] &= ok_p
            print(f"{f'matmul_packed w{bits}':<18} {f'{M}x{K}x{N}':<18} {'-':>8} {str(ok_p):>11} {'-':>14}")

    # temporal (thermometer) validation path, small shapes only
    for bits in (2, 4):
        m = 1 << (bits - 1)
        a = jax.random.randint(key, (32, 16), -m, m, dtype=jnp.int32).astype(jnp.int8)
        b = jax.random.randint(key, (16, 32), -m, m, dtype=jnp.int32).astype(jnp.int8)
        y = ops.temporal_gemm(a, b, bitwidth=bits, impl="xla")
        ok = bool((y == matmul_int_ref(a, b)).all())
        out["exact"] &= ok
        print(f"{f'temporal_gemm w{bits}':<18} {'32x16x32':<18} {'-':>8} {str(ok):>11} {'-':>14}")

    # fused per-token-scale path (PR 9 kernel): interpret-Pallas vs XLA
    # bit-exact through the full qlinear layer, and the Pallas path must
    # record zero fallbacks — the downgrade this PR removed stays removed
    ops.reset_kernel_counters()
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(0, 1, (48, 96)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.1, (96, 64)), jnp.float32)
    be_x = GemmBackend("int8", impl="xla", fused=True, act_scale="token")
    be_p = GemmBackend("int8", impl="pallas_interpret", fused=True,
                       act_scale="token")
    y_x = gemm(x, w, backend=be_x, name="bench.pertoken")
    y_p = gemm(x, w, backend=be_p, name="bench.pertoken")
    ok = bool((np.asarray(y_x) == np.asarray(y_p)).all())
    out["exact"] &= ok
    fb = ops.kernel_counters()["fallbacks"].get("bench.pertoken", {})
    assert not fb, f"per-token fused matmul fell back to XLA: {fb}"
    print(f"{'fused per-token':<18} {'48x96x64':<18} {'-':>8} {str(ok):>11} "
          f"{str(ok):>14}  (pallas fallbacks: 0)")


def bench_fused_pipeline(shapes, out, iters=10):
    """A/B the full dynamic-quant linear layer: fused vs unfused, XLA path."""
    rng = np.random.default_rng(0)
    print(f"\n{'pipeline (int8 dynamic+stats-off)':<34} {'unfused ms':>11} {'fused ms':>9} "
          f"{'speedup':>8} {'GMAC/s':>8} {'disp u→f':>9}")
    results = {}
    for (M, K, N) in shapes:
        x = jnp.asarray(rng.normal(0, 1, (M, K)), jnp.float32)
        w = jnp.asarray(rng.normal(0, 0.1, (K, N)), jnp.float32)
        b = jnp.asarray(rng.normal(0, 0.1, (N,)), jnp.float32)
        be_f = GemmBackend("int8", impl="xla", fused=True)
        be_u = GemmBackend("int8", impl="xla", fused=False)

        y_f = gemm(x, w, backend=be_f, bias=b)
        y_u = gemm(x, w, backend=be_u, bias=b)
        exact = bool((y_f == y_u).all())
        out["exact"] &= exact

        t_u = _time(lambda x, w: gemm(x, w, backend=be_u, bias=b), x, w, iters=iters)
        t_f = _time(lambda x, w: gemm(x, w, backend=be_f, bias=b), x, w, iters=iters)

        # dispatch counts include the stats sweeps (the profiling configuration)
        with ops.counting_dispatches() as log_u:
            gemm(x, w, backend=be_u.with_stats(), bias=b)
        with ops.counting_dispatches() as log_f:
            gemm(x, w, backend=be_f.with_stats(), bias=b)

        gmacs = M * K * N / t_f / 1e9
        tag = f"{M}x{K}x{N}"
        results[tag] = {
            "unfused_ms": t_u * 1e3,
            "fused_ms": t_f * 1e3,
            "speedup": t_u / t_f,
            "fused_gmacs": gmacs,
            "dispatches_unfused": len(log_u),
            "dispatches_fused": len(log_f),
            "bit_exact": exact,
        }
        print(f"{tag:<34} {t_u*1e3:>11.2f} {t_f*1e3:>9.2f} {t_u/t_f:>7.2f}x "
              f"{gmacs:>8.1f} {len(log_u):>4}→{len(log_f)}")
    out["pipeline"] = results
    worst = min(r["speedup"] for r in results.values())
    dmax = max(r["dispatches_fused"] for r in results.values())
    print(f"\nfused pipeline: min speedup {worst:.2f}x, max dispatches {dmax}")


def _measure_bound(jitted, args, hw, iters):
    """(hlo_bytes, memory_bound_s, measured_s) for one compiled callable."""
    from repro.roofline.hlo_parse import parse_hlo

    compiled = jitted.lower(*args).compile()
    nbytes = float(parse_hlo(compiled.as_text()).hbm_bytes)
    jax.block_until_ready(jitted(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        r = jitted(*args)
    jax.block_until_ready(r)
    dt = (time.perf_counter() - t0) / iters
    return nbytes, nbytes / hw.hbm_bw, dt


def bench_roofline_gate(fast: bool, out: dict, iters: int = 10) -> None:
    """Gate the two serving hot-path kernels against their memory-bound
    roofline (DESIGN.md §13): price each compiled call's optimized-HLO byte
    traffic under the running backend's HW profile and report

        fraction = (HLO_bytes / hbm_bw) / measured_s

    — the fraction of the memory-bound bound the kernel actually achieves.
    Fractions below the declared ROOFLINE_FLOORS hard-fail on accelerator
    backends; on CPU the numbers are report-only (CPU runs the XLA twins and
    the cpu HW profile is a class estimate, not a calibration)."""
    from repro.models.attention import KVView, _quantize_kv, kv_cache_read
    from repro.models.flash import blockwise_attention, paged_decode_attention
    from repro.roofline.analysis import hw_profile

    backend = jax.default_backend()
    hw = hw_profile("auto")
    enforce = backend in ("tpu", "gpu")
    rng = np.random.default_rng(0)
    results: dict = {}

    # fused per-token tuGEMM — the serving linear-layer hot path
    M, K, N = (128, 512, 512) if fast else (512, 2048, 2048)
    x = jnp.asarray(rng.normal(0, 1, (M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.1, (K, N)), jnp.float32)
    be = GemmBackend("int8", fused=True, act_scale="token")  # impl=auto
    gemm_fn = jax.jit(
        lambda x, w: gemm(x, w, backend=be, name="roofline.gemm"))
    results["tugemm_fused_pertoken"] = _measure_bound(gemm_fn, (x, w), hw, iters)

    # paged flash-decode — the serving attention hot path (int8 KV pool)
    kv, group, hd, bs, MB, B = (2, 2, 32, 8, 4, 4) if fast else (4, 4, 64, 16, 8, 8)
    P = B * MB
    kq, ks = _quantize_kv(jnp.asarray(
        rng.standard_normal((P + 1, bs, kv, hd)).astype(np.float32)))
    vq, vs = _quantize_kv(jnp.asarray(
        rng.standard_normal((P + 1, bs, kv, hd)).astype(np.float32)))
    tables = jnp.arange(P, dtype=jnp.int32).reshape(B, MB)
    pos = jnp.full((B,), MB * bs - 1, jnp.int32)   # full rows, decode step
    lens = jnp.ones((B,), jnp.int32)
    q = jnp.asarray(
        rng.standard_normal((B, 1, kv * group, hd)).astype(np.float32))

    def step(q, kq, ks, vq, vs, tables, pos, lens):
        view = KVView(pos, lens, tables, block_size=bs, layout="paged")
        cache = {"k": kq, "k_scale": ks, "v": vq, "v_scale": vs}
        o = paged_decode_attention(q, cache, ("k",), "v", view,
                                   kv_heads=kv, name="roofline.paged")
        if o is None:  # CPU: the XLA twin is the path serving actually runs
            kf = kv_cache_read(cache, "k", q.dtype, kv_len=view.kv_len, view=view)
            vf = kv_cache_read(cache, "v", q.dtype, kv_len=view.kv_len, view=view)
            o = blockwise_attention(q, kf, vf, q_offset=view.pos,
                                    kv_len=view.kv_len, causal=True)
        return o

    results["flash_paged_decode"] = _measure_bound(
        jax.jit(step), (q, kq, ks, vq, vs, tables, pos, lens), hw, iters)

    print(f"\n{'roofline gate (' + hw.name + ' profile)':<34} {'HLO MB':>8} "
          f"{'bound us':>9} {'meas us':>8} {'frac':>6} {'floor':>6} {'gate':>7}")
    gate: dict = {}
    failures = []
    for name, (nbytes, bound_s, meas_s) in results.items():
        frac = bound_s / meas_s if meas_s else 0.0
        floor = ROOFLINE_FLOORS[name]
        ok = frac >= floor
        gate[name] = {
            "hlo_bytes": nbytes,
            "memory_bound_s": bound_s,
            "measured_s": meas_s,
            "fraction": frac,
            "floor": floor,
            "enforced": enforce,
            "hw": hw.name,
        }
        verdict = ("pass" if ok else "FAIL") if enforce else "report"
        print(f"{name:<34} {nbytes/1e6:>8.2f} {bound_s*1e6:>9.1f} "
              f"{meas_s*1e6:>8.1f} {frac:>6.3f} {floor:>6.2f} {verdict:>7}")
        if enforce and not ok:
            failures.append(f"{name}: {frac:.3f} < floor {floor}")
    out["roofline"] = gate
    if failures:
        raise RuntimeError(
            "roofline gate failed on accelerator backend "
            f"{backend}: {'; '.join(failures)}")


def bench_e2e(fast: bool, write_json: bool) -> dict:
    """Quantized-vs-fp32 decode-step A/B on the smoke model (XLA path)."""
    import dataclasses

    from repro.configs.base import RunConfig, get_config
    from repro.core.report import slot_energy
    from repro.models import init, init_caches
    from repro.quant import apply_surgery, tree_totals
    from repro.serve import build_decode, build_prefill

    cfg = get_config("qwen3-0.6b_smoke")
    rc0 = RunConfig(dtype="float32", param_dtype="float32", remat="none")
    params = init(cfg, rc0, jax.random.PRNGKey(0))
    B, T, cap = 4, 8, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    nxt = jnp.ones((B, 1), jnp.int32)
    pos = jnp.asarray(T, jnp.int32)
    iters = 5 if fast else 20

    variants = {
        "fp32": rc0,
        "int8_dynamic": dataclasses.replace(rc0, quant_policy="*=int8"),
        "int4_dynamic": dataclasses.replace(rc0, quant_policy="*=int4"),
        "int4_prequant": dataclasses.replace(rc0, quant_policy="*=int4:prequant"),
    }
    out: dict = {"backend": jax.default_backend(), "fast": fast, "variants": {}}
    ref_logits = None
    print(f"\n{'e2e decode step (B=4, smoke model)':<26} {'ms/step':>9} "
          f"{'corr vs fp32':>13} {'Mcycles':>9} {'energy/step':>12}")
    for name, rc in variants.items():
        p = apply_surgery(cfg, rc, params)
        caches = init_caches(cfg, rc, B, cap)
        caches, _ = jax.jit(build_prefill(cfg, rc))(p, caches, {"tokens": toks})
        quant = effective_policy(rc).is_quant
        dec = jax.jit(build_decode(cfg, rc, with_stats=quant))
        res = dec(p, caches, nxt, pos)
        jax.block_until_ready(res)
        t0 = time.perf_counter()
        for _ in range(iters):
            res = dec(p, caches, nxt, pos)
        jax.block_until_ready(res)
        dt = (time.perf_counter() - t0) / iters
        logits = np.asarray(res[1])
        if name == "fp32":
            ref_logits = logits
            corr = 1.0
        else:
            corr = float(np.corrcoef(logits.ravel(), ref_logits.ravel())[0, 1])
        entry = {"ms_per_step": dt * 1e3, "corr_vs_fp32": corr}
        if quant:
            tot = tree_totals(res[2])
            e_j = sum(
                slot_energy(b, "serial", t["serial_cycles"])[1]
                for b, t in tree_totals_by_bits(res[2]).items()
            )
            entry.update(
                serial_cycles=tot["serial_cycles"],
                parallel_cycles=tot["parallel_cycles"],
                energy_j_16x16_serial=e_j,
            )
            extra = f"{tot['serial_cycles']/1e6:>9.2f} {e_j*1e6:>10.2f}uJ"
        else:
            extra = f"{'-':>9} {'-':>12}"
        out["variants"][name] = entry
        print(f"{name:<26} {dt*1e3:>9.2f} {corr:>13.4f} {extra}")

    if write_json:
        emit(_OUT_E2E, out["backend"], out)
    return out


def bench_policy(fast: bool, write_json: bool) -> dict:
    """Mixed-policy e2e cell: uniform int8 vs the exploration paper's mixed
    deployment (attention int8 / MLP int2) on a decode step — per-bits cycle
    split, modeled 16×16-unit energy, and logits correlation vs fp32.
    Writes ``benchmarks/BENCH_policy.json``."""
    import dataclasses

    from repro.configs.base import RunConfig, get_config
    from repro.core.report import energy_report
    from repro.models import init, init_caches
    from repro.serve import build_decode, build_prefill

    cfg = get_config("qwen3-0.6b_smoke")
    rc0 = RunConfig(dtype="float32", param_dtype="float32", remat="none")
    params = init(cfg, rc0, jax.random.PRNGKey(0))
    B, T, cap = 4, 8, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    nxt = jnp.ones((B, 1), jnp.int32)
    pos = jnp.asarray(T, jnp.int32)
    iters = 5 if fast else 20

    policies = {
        "uniform_int8": "*=int8",
        "mixed_int8attn_int2mlp": "attn.*=int8,mlp.*=int2,*=bf16",
    }
    out: dict = {"backend": jax.default_backend(), "fast": fast, "policies": {}}
    ref_logits = None
    # fp32 reference logits for the correlation column
    caches = init_caches(cfg, rc0, B, cap)
    caches, _ = jax.jit(build_prefill(cfg, rc0))(params, caches, {"tokens": toks})
    ref_logits = np.asarray(jax.jit(build_decode(cfg, rc0))(params, caches, nxt, pos)[1])

    print(f"\n{'mixed-policy decode A/B':<26} {'ms/step':>9} {'corr':>7} "
          f"{'Mcyc(ser)':>10} {'energy/step':>12}  cycles by bits")
    for name, pol in policies.items():
        rc = dataclasses.replace(rc0, quant_policy=pol)
        caches = init_caches(cfg, rc, B, cap)
        caches, _ = jax.jit(build_prefill(cfg, rc))(params, caches, {"tokens": toks})
        dec = jax.jit(build_decode(cfg, rc, with_stats=True))
        res = dec(params, caches, nxt, pos)
        jax.block_until_ready(res)
        t0 = time.perf_counter()
        for _ in range(iters):
            res = dec(params, caches, nxt, pos)
        jax.block_until_ready(res)
        dt = (time.perf_counter() - t0) / iters
        corr = float(np.corrcoef(np.asarray(res[1]).ravel(), ref_logits.ravel())[0, 1])
        rep = energy_report(res[2], variant="serial")
        by_bits = {
            str(b): {"cycles": s["cycles"], "energy_j": s["energy_j"],
                     "layers": s["layers"]}
            for b, s in rep.by_bits.items()
        }
        out["policies"][name] = {
            "policy": pol,
            "ms_per_step": dt * 1e3,
            "corr_vs_fp32": corr,
            "serial_cycles": rep.total_cycles,
            "energy_j_16x16_serial": rep.unit_energy_j,
            "by_bits": by_bits,
        }
        bb = ", ".join(f"int{b}:{s['cycles']}" for b, s in sorted(by_bits.items(), reverse=True))
        print(f"{name:<26} {dt*1e3:>9.2f} {corr:>7.4f} {rep.total_cycles/1e6:>10.2f} "
              f"{rep.unit_energy_j*1e6:>10.2f}uJ  {bb}")

    u = out["policies"]["uniform_int8"]
    m = out["policies"]["mixed_int8attn_int2mlp"]
    if m["energy_j_16x16_serial"] > 0:
        out["mixed_energy_ratio"] = u["energy_j_16x16_serial"] / m["energy_j_16x16_serial"]
        print(f"mixed policy energy: {out['mixed_energy_ratio']:.2f}x less than uniform int8")
    if write_json:
        emit(_OUT_POLICY, out["backend"], out)
    return out


def run(fast: bool = False, write_json: bool | None = None) -> dict:
    # default: only full-shape runs refresh the committed BENCH_kernels.json —
    # a --fast run must never silently clobber the perf-trajectory baseline
    if write_json is None:
        write_json = not fast
    shapes = [(64, 64, 64), (128, 256, 128)] if fast else [
        (64, 64, 64), (128, 256, 128), (256, 512, 256), (512, 512, 512),
    ]
    out = {
        "backend": jax.default_backend(),
        "fast": fast,
        "exact": True,
        "timings": {},
    }
    bench_exactness(shapes, out)
    bench_fused_pipeline(shapes, out, iters=5 if fast else 10)
    bench_roofline_gate(fast, out, iters=5 if fast else 10)
    print(f"\nall kernels bit-exact: {out['exact']}")
    if write_json:
        emit(_OUT, out["backend"], out)
    else:
        # --fast must still prove the per-backend trajectory store works:
        # schema round-trip, history append, v1 migration — in memory only
        check_store_roundtrip(out["backend"], out)
    out["e2e"] = bench_e2e(fast, write_json)
    out["policy"] = bench_policy(fast, write_json)
    return out


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--fast", action="store_true", help="small shapes only")
    p.add_argument("--no-json", action="store_true", help="skip BENCH_kernels.json")
    args = p.parse_args()
    run(fast=args.fast, write_json=False if args.no_json else None)
