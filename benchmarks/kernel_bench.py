"""Kernel micro-benchmark: exactness sweep + CPU wall time per dispatch path.

For each kernel (int8 GEMM, packed int4/int2 GEMM, thermometer-decomposed
temporal GEMM, quantize) sweeps shapes and checks bit-exactness of the
Pallas body (interpret mode) and the XLA path against the jnp oracle, then
times the XLA path (what CPU users run; TPU would run the compiled Pallas
kernels, which cannot be timed here).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.ref import matmul_int_ref


def _rand_int8(key, shape, bits=8):
    m = 1 << (bits - 1)
    return jax.random.randint(key, shape, -m, m, dtype=jnp.int32).astype(jnp.int8)


def _time(fn, *args, iters=5):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def run(fast: bool = False) -> dict:
    key = jax.random.PRNGKey(0)
    shapes = [(64, 64, 64), (128, 256, 128)] if fast else [
        (64, 64, 64), (128, 256, 128), (256, 512, 256), (512, 512, 512),
    ]
    out = {"exact": True, "timings": {}}
    print(f"\n{'kernel':<18} {'shape':<18} {'xla ms':>8} {'exact(xla)':>11} {'exact(interp)':>14}")
    for (M, K, N) in shapes:
        ka, kb = jax.random.split(jax.random.fold_in(key, M * N))
        a = _rand_int8(ka, (M, K))
        b = _rand_int8(kb, (K, N))
        ref = matmul_int_ref(a, b)

        y_xla = ops.matmul_int8(a, b, impl="xla")
        ok_x = bool((y_xla == ref).all())
        ok_i = True
        if M <= 128:  # interpret mode is python-slow; keep it to small shapes
            y_int = ops.matmul_int8(a, b, impl="pallas_interpret")
            ok_i = bool((y_int == ref).all())
        dt = _time(lambda a, b: ops.matmul_int8(a, b, impl="xla"), a, b)
        out["exact"] &= ok_x and ok_i
        out["timings"][f"int8_{M}x{K}x{N}"] = dt * 1e3
        gmacs = M * K * N / dt / 1e9
        print(f"{'matmul_int8':<18} {f'{M}x{K}x{N}':<18} {dt*1e3:>8.2f} {str(ok_x):>11} {str(ok_i):>14}  ({gmacs:.1f} GMAC/s)")

        for bits in (4, 2):
            mb = 1 << (bits - 1)
            a_s = jnp.clip(a, -mb, mb - 1)
            b_s = jnp.clip(b, -mb, mb - 1)
            packed = ops.pack_weights(b_s, bits)
            y_p = ops.matmul_packed(a_s, packed, bits=bits, impl="xla")
            ref_p = matmul_int_ref(a_s, b_s)
            ok_p = bool((y_p == ref_p).all())
            out["exact"] &= ok_p
            print(f"{f'matmul_packed w{bits}':<18} {f'{M}x{K}x{N}':<18} {'-':>8} {str(ok_p):>11} {'-':>14}")

    # temporal (thermometer) validation path, small shapes only
    for bits in (2, 4):
        m = 1 << (bits - 1)
        a = jax.random.randint(key, (32, 16), -m, m, dtype=jnp.int32).astype(jnp.int8)
        b = jax.random.randint(key, (16, 32), -m, m, dtype=jnp.int32).astype(jnp.int8)
        y = ops.temporal_gemm(a, b, bitwidth=bits, impl="xla")
        ok = bool((y == matmul_int_ref(a, b)).all())
        out["exact"] &= ok
        print(f"{f'temporal_gemm w{bits}':<18} {'32x16x32':<18} {'-':>8} {str(ok):>11} {'-':>14}")
    print(f"\nall kernels bit-exact: {out['exact']}")
    return out


if __name__ == "__main__":
    run()
