"""Sharded-vs-dense serving A/B (parallel/serve_mesh.py, DESIGN.md §12).

Runs the same prompt set through the single-device scheduler and the
dp=2 × tp=4 sharded scheduler (8-device host-platform CPU mesh) at a mixed
int8/int2 policy and reports:

- tokens/s for both engines (CPU shard_map is a *correctness* vehicle — the
  mesh overhead on 8 host threads is reported, not celebrated)
- bytes-on-wire by bitwidth from the trace-time collective meter, against
  the bf16 bytes the same gathers would have moved — the
  quantize-before-all-gather win (≤ bits/16, asserted)
- per-device cycle balance from the exact integer attribution (max/mean of
  the per-device serial-cycle shares)
- page-ownership balance across tp groups (BlockManager.table_shard)

Greedy tokens MUST match bit-for-bit between the two engines; any mismatch
is a hard SystemExit (this is the PR's gate, not a soft metric).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python benchmarks/shard_bench.py          # writes JSON
    ... shard_bench.py --fast                                    # smoke, no JSON
"""

from __future__ import annotations

import argparse
import json
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig, get_config
from repro.models.transformer import model_spec
from repro.parallel.sharding import materialize
from repro.serve import Request, Scheduler

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "BENCH_shard.json")

GQA = ModelConfig(
    name="gqa_shard_bench", family="dense", attn_type="gqa",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=4, d_ff=128,
    vocab_size=128, tie_embeddings=False,
)

CASES = [
    ("gqa_int8_int2", GQA, "attn.*=int8,mlp.*=int2,*=bf16"),
    ("mla_moe_int8_int2", "deepseek-v2-lite-16b_smoke",
     "mla.*=int8,moe.*=int2,mlp.*=int2,*=bf16"),
]


def _drive(cfg, rc, params, prompts, mesh, max_new):
    eng = Scheduler(cfg, rc, params, capacity=64, max_batch=4,
                    track_energy=True, mesh=mesh)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=list(p), max_new=max_new))
    t0 = time.perf_counter()
    done = eng.run()
    jax.effects_barrier()
    wall = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    return eng, {r.rid: list(r.out) for r in done}, toks, wall


def run(fast: bool = False) -> dict:
    if jax.device_count() < 8:
        msg = (f"skipped: {jax.device_count()} devices "
               "(needs XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        print(f"[shard_bench] {msg}")
        return {"skipped": msg}

    rng = np.random.default_rng(11)
    n_req, max_new = (4, 4) if fast else (8, 8)
    out: dict = {"mesh": "dp=2,tp=4", "devices": 8, "cases": {}}

    for name, cfg_ref, policy in CASES[: 1 if fast else 2]:
        cfg = get_config(cfg_ref) if isinstance(cfg_ref, str) else cfg_ref
        rc = RunConfig(
            quant_policy=policy, kv_layout="paged", kv_cache_dtype="int8",
            block_size=8, dtype="float32", param_dtype="float32",
            prefill_chunk=8,
        )
        params = materialize(model_spec(cfg), jax.random.PRNGKey(0), jnp.float32)
        prompts = [[int(t) for t in rng.integers(0, cfg.vocab_size,
                                                 rng.integers(4, 14))]
                   for _ in range(n_req)]

        ref, ref_toks, n_ref, wall_ref = _drive(cfg, rc, params, prompts,
                                                None, max_new)
        shd, shd_toks, n_shd, wall_shd = _drive(cfg, rc, params, prompts,
                                                "2,4", max_new)

        if shd_toks != ref_toks:
            raise SystemExit(
                f"[shard_bench] {name}: sharded greedy tokens DIVERGED from "
                f"the single-device run — the bit-exactness gate failed")
        if shd.cycles_by_bits != ref.cycles_by_bits:
            raise SystemExit(
                f"[shard_bench] {name}: merged cycle totals diverged")

        comms = shd.comms_summary()
        wire = {}
        for b, r in sorted(comms["by_bits"].items()):
            wire[str(b)] = {
                "payload_bytes": r["payload_bytes"],
                "scale_bytes": r["scale_bytes"],
                "bf16_bytes": r["bf16_bytes"],
                "ratio_vs_bf16": (r["payload_bytes"] / r["bf16_bytes"]
                                  if r["bf16_bytes"] else 0.0),
            }
            if b < 16 and r["payload_bytes"] * 16 > r["bf16_bytes"] * max(b, 8):
                raise SystemExit(
                    f"[shard_bench] {name}: int{b} gather moved more than "
                    f"bits/16 of the bf16 volume")

        att = shd.device_attribution()
        balance = {}
        for b, shares in att.items():
            s = shares.astype(np.float64).reshape(-1)
            balance[str(b)] = {
                "per_device_cycles": [int(v) for v in s],
                "max_over_mean": float(s.max() / s.mean()) if s.mean() else 1.0,
            }

        pages = [int((shd.mgr.table_shard(r, 4) != shd.mgr.trash).sum())
                 for r in range(4)]

        case = {
            "policy": policy,
            "requests": n_req,
            "tokens": n_shd,
            "dense_tokens_per_s": n_ref / wall_ref if wall_ref else 0.0,
            "sharded_tokens_per_s": n_shd / wall_shd if wall_shd else 0.0,
            "bit_exact": True,
            "wire_bytes_by_bits": wire,
            "wire_bytes_total": comms["bytes_moved"],
            "bf16_bytes_equivalent": comms["bf16_bytes"],
            "device_cycle_balance": balance,
            "tp_page_ownership": pages,
            "moe_dropped_tokens": shd.moe_dropped_tokens,
        }
        out["cases"][name] = case
        print(f"[shard_bench] {name}: bit-exact ✓  "
              f"{case['sharded_tokens_per_s']:.1f} tok/s sharded vs "
              f"{case['dense_tokens_per_s']:.1f} single  "
              f"wire {case['wire_bytes_total']} B "
              f"(bf16 {case['bf16_bytes_equivalent']} B)")

    if not fast:
        with open(OUT, "w") as f:
            json.dump(out, f, indent=1)
        print(f"[shard_bench] wrote {OUT}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    run(fast=args.fast)
