"""Observability overhead A/B + Chrome-trace schema smoke (DESIGN.md §14).

Two checks, both hard gates:

1. **Overhead A/B** — the identical bursty trace driven through two warm
   scheduler engines, one with a live ``Tracer`` + energy tracking, one
   with tracing disabled. Each arm runs best-of-N warm passes (pass 0
   compiles and is discarded). Tracing must cost <3% decode tokens/s —
   the instrumentation budget promised in DESIGN.md §14 — or the script
   exits 1.

2. **Overloaded mini-trace** — a short 2x-overload run (tiny queue bound,
   budget-capped tenant, binding TTLs) with tracing on, exported and
   re-validated against the Chrome trace-event schema. The trace must
   contain the full span taxonomy (tick phases + per-request lifecycle),
   the pool/queue/ladder/energy counter tracks, and at least one
   shed-or-reject instant — i.e. the trace is useful precisely when the
   server is in trouble.

    PYTHONPATH=src python benchmarks/obs_bench.py          # full, writes JSON
    PYTHONPATH=src python benchmarks/obs_bench.py --fast   # CI smoke, writes JSON
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs.base import RunConfig, get_config
from repro.models import init
from repro.obs.trace import Tracer, trace_summary, validate_chrome_trace
from repro.serve import AdmissionController, Request, Scheduler

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "BENCH_obs.json")
TRACE_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "obs_trace_overload.json")

OVERHEAD_BUDGET = 0.03  # fraction of decode tokens/s tracing may cost

# span/counter/instant names the overload trace must contain to be useful
REQUIRED_SPANS = {"tick", "admit", "plan", "device_step", "commit", "queued"}
REQUIRED_COUNTERS = {"pool_pages", "queue_depth", "ladder_level",
                     "modeled_power_mw", "modeled_energy_mj"}
REQUIRED_INSTANTS = {"submit", "admit", "finish"}


def bursty_trace(rng, *, requests, min_prompt, max_prompt, burst, gap, max_new,
                 rid0=0):
    trace = []
    for i in range(requests):
        arrival = (i // burst) * gap
        plen = int(rng.integers(min_prompt, max_prompt + 1))
        trace.append((arrival, Request(
            rid=rid0 + i, prompt=rng.integers(0, 256, plen).tolist(),
            max_new=max_new)))
    return trace


def drive(eng, trace, max_steps=10_000):
    reqs = [Request(r.rid, list(r.prompt), r.max_new) for _, r in trace]
    pending = sorted(zip([a for a, _ in trace], reqs), key=lambda t: t[0])
    t0 = time.perf_counter()
    step = 0
    while step < max_steps:
        while pending and pending[0][0] <= step:
            eng.submit(pending.pop(0)[1])
        ran = eng.tick()
        if not ran and not pending and not eng.queue:
            break
        step += 1
    jax.effects_barrier()
    return time.perf_counter() - t0, sum(len(r.out) for r in reqs)


def run_ab(cfg, rc, params, *, passes, trace_kw, pool, max_batch, capacity):
    """Interleaved overhead A/B: two warm engines (one traced, one not),
    alternating measurement passes of the identical trace shape (fresh rids
    per pass so each engine treats them as new work). Interleaving is the
    point -- on a shared host, measuring one arm's passes in a separate time
    window from the other's folds clock-frequency/contention drift into the
    "overhead", dwarfing the ~2us/event tracer cost. Best-of-N per arm then
    discards transient slowdowns. track_energy stays off in BOTH arms: it
    swaps in the with_stats step variant, a modeling feature with its own
    cost -- this A/B isolates pure tracing (--trace without --energy)."""
    engines = {}
    for label, tracer in [("off", None), ("on", Tracer())]:
        engines[label] = Scheduler(
            cfg, rc, params, capacity=capacity, max_batch=max_batch,
            num_pages=pool, temperature=0.0, tracer=tracer)
    best = {"off": 0.0, "on": 0.0}
    rid0 = {"off": 0, "on": 0}

    def one_pass(label, warm):
        rng = np.random.default_rng(7)  # identical trace shape every pass
        trace = bursty_trace(rng, rid0=rid0[label], **trace_kw)
        rid0[label] += len(trace)
        wall, toks = drive(engines[label], trace)
        if not warm:
            best[label] = max(best[label], toks / wall if wall else 0.0)

    for label in ("off", "on"):  # pass 0 pays the compiles, discarded
        one_pass(label, warm=True)
    for _ in range(passes):
        for label in ("off", "on"):
            one_pass(label, warm=False)
    return best["off"], best["on"]


def run_overload_trace(cfg, rc, params, *, pool, max_batch, capacity,
                       requests, max_new, chunk):
    """Short 2x-overload run with tracing on; returns (tracer, health)."""
    rng = np.random.default_rng(3)
    service = max_batch / (max_new + 2.0)
    gap = max(1, round(1.0 / (2.0 * service)))
    pris = ["realtime", "interactive", "batch"]
    arrivals = []
    for rid in range(requests):
        plen = int(rng.integers(chunk, 3 * chunk + 1))
        r = Request(rid=rid, prompt=rng.integers(0, 256, plen).tolist(),
                    max_new=max_new)
        r.priority = pris[rid % 3]
        r.tenant = f"t{rid % 2}"
        arrivals.append((rid * gap, r))
    t1_demand = sum(len(r.prompt) + r.max_new for _, r in arrivals
                    if r.tenant == "t1")
    horizon = max_new + 3 * chunk
    adm = AdmissionController(
        max_queue=max(max_batch, 2),
        tenant_budgets={"t1": int(0.5 * t1_demand)},
        default_ttl={"realtime": 2 * horizon, "interactive": 4 * horizon,
                     "batch": 8 * horizon},
    )
    tracer = Tracer()
    eng = Scheduler(cfg, rc, params, capacity=capacity, max_batch=max_batch,
                    num_pages=pool, admission=adm, tracer=tracer,
                    track_energy=True)
    pending = list(arrivals)
    step = 0
    while step < 10_000:
        while pending and pending[0][0] <= step:
            eng.submit(pending.pop(0)[1])
        ran = eng.tick()
        if not ran and not pending and not eng.queue:
            break
        step += 1
    jax.effects_barrier()
    return tracer, eng.health()


def check_trace(obj):
    """Schema + taxonomy gate; returns the summary dict or raises SystemExit."""
    validate_chrome_trace(obj)
    spans, counters, instants = set(), set(), set()
    for ev in obj["traceEvents"]:
        ph = ev.get("ph")
        if ph == "X":
            spans.add(ev["name"])
        elif ph == "C":
            counters.add(ev["name"])
        elif ph == "i":
            instants.add(ev["name"])
    missing = [("span", n) for n in sorted(REQUIRED_SPANS - spans)]
    missing += [("counter", n) for n in sorted(REQUIRED_COUNTERS - counters)]
    missing += [("instant", n) for n in sorted(REQUIRED_INSTANTS - instants)]
    if missing:
        raise SystemExit(f"[obs_bench] trace schema FAILED: missing {missing}")
    if not ({"shed", "reject"} & instants):
        raise SystemExit("[obs_bench] trace schema FAILED: overload run "
                         "produced neither shed nor reject instants")
    s = trace_summary(obj)
    s["span_names"] = sorted(spans)
    s["counter_names"] = sorted(counters)
    s["instant_names"] = sorted(instants)
    return s


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b_smoke")
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: smaller trace, fewer passes")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--passes", type=int, default=4,
                    help="interleaved warm passes per arm (best-of-N)")
    args = ap.parse_args(argv)
    if args.fast:
        args.requests, args.max_new, args.passes = 6, 16, 3

    cfg = get_config(args.arch)
    rc = RunConfig(dtype="float32", param_dtype="float32", remat="none",
                   kv_cache_dtype="int8", block_size=16, prefill_chunk=16,
                   kv_layout="paged")
    params = init(cfg, rc, jax.random.PRNGKey(0))

    from repro.serve.cache import num_pages_for

    pool = num_pages_for(args.capacity, rc.block_size, args.max_batch)
    trace_kw = dict(requests=args.requests, min_prompt=16,
                    max_prompt=min(args.capacity - args.max_new - 2, 48),
                    burst=max(args.max_batch // 2, 1), gap=3,
                    max_new=args.max_new)
    kw = dict(pool=pool, max_batch=args.max_batch, capacity=args.capacity)

    # ---- overhead A/B, interleaved (see run_ab docstring)
    rate_off, rate_on = run_ab(cfg, rc, params, passes=args.passes,
                               trace_kw=trace_kw, **kw)
    overhead = 1.0 - rate_on / max(rate_off, 1e-9)
    print(f"[obs_bench] decode rate: untraced {rate_off:8.2f} tok/s, "
          f"traced {rate_on:8.2f} tok/s -> overhead {overhead*100:+.2f}% "
          f"(budget {OVERHEAD_BUDGET*100:.0f}%)")

    # ---- overloaded mini-trace + schema check
    chunk = rc.prefill_chunk
    tracer, health = run_overload_trace(
        cfg, rc, params, requests=2 * args.requests,
        max_new=max(args.max_new // 2, 4), chunk=chunk, **kw)
    obj = tracer.to_dict()
    summary = check_trace(obj)
    tracer.export(TRACE_OUT)
    print(f"[obs_bench] overload trace OK: {summary['events']} events, "
          f"{summary['spans']} spans, {summary['request_tracks']} request "
          f"tracks, instants {summary['instant_names']} -> {TRACE_OUT}")

    out = {
        "arch": args.arch,
        "scenario": {"requests": args.requests, "max_new": args.max_new,
                     "max_batch": args.max_batch, "capacity": args.capacity,
                     "passes": args.passes, "pool_pages": pool,
                     "fast": args.fast},
        "tokens_per_s_untraced": rate_off,
        "tokens_per_s_traced": rate_on,
        "overhead_fraction": overhead,
        "overhead_budget": OVERHEAD_BUDGET,
        "overload_trace": {k: summary[k] for k in
                           ("events", "spans", "counters", "instants",
                            "request_tracks")},
        "latency": health["latency"],
    }
    with open(OUT, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[obs_bench] wrote {OUT}")

    if overhead > OVERHEAD_BUDGET:
        raise SystemExit(f"[obs_bench] FAILED: tracing overhead "
                         f"{overhead*100:.2f}% exceeds "
                         f"{OVERHEAD_BUDGET*100:.0f}% budget")
    return out


def run(fast: bool = False):
    """benchmarks.run entry point (aggregated into the harness JSON)."""
    return main(["--fast"] if fast else [])


if __name__ == "__main__":
    main()
