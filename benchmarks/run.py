"""Benchmark harness: one runner per paper table/figure + framework benches.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --fast     # reduced sizes
    PYTHONPATH=src python -m benchmarks.run --only fig5_maxval_profile
"""

from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import time
import traceback

BENCHES = [
    "table1_ppa",           # Table I: post-synthesis PPA, 12 datapoints
    "fig4_comparison",      # Fig 4: tuGEMM vs uGEMM PPA ratios
    "latency_eval",         # §III-B: worst/avg-case latency
    "fig5_maxval_profile",  # Fig 5: max-value profiling -> avg-case speedup
    "accuracy_mlp",         # §III-B.2: exact vs stochastic accuracy
    "kernel_bench",         # kernels: exactness sweep + µs/call
    "serve_bench",          # paged KV + chunked-prefill vs legacy engine
    "spec_bench",           # speculative int2-draft decode vs PR 4 baseline
    "shard_bench",          # dp×tp sharded vs single-device A/B (8-dev mesh)
    "edge_planner",         # §IV: deployment planner (beyond paper)
    "roofline_all",         # deliverable (g): aggregate dry-run rooflines
]


def bench_meta() -> dict:
    """Provenance stamped on every bench emit: the accelerator backend the
    numbers were produced on and the git rev they measure. Without these a
    trajectory file can't distinguish a regression from a machine change."""
    try:
        import jax
        backend = jax.default_backend()
    except Exception:  # noqa: BLE001
        backend = "unknown"
    try:
        rev = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=pathlib.Path(__file__).resolve().parent,
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip()
    except Exception:  # noqa: BLE001
        rev = "unknown"
    return {"backend": backend, "git_rev": rev}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    meta = bench_meta()
    print(f"[bench] backend={meta['backend']} git_rev={meta['git_rev']}")
    results, failures = {}, []
    for name in BENCHES:
        if args.only and name != args.only:
            continue
        print(f"\n{'='*78}\n== {name}\n{'='*78}")
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            res = mod.run(fast=args.fast)
            if isinstance(res, dict):
                res = dict(res, _meta=meta)
            results[name] = res
            print(f"-- {name} done in {time.time()-t0:.1f}s "
                  f"[{meta['backend']}@{meta['git_rev']}]")
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"-- {name} FAILED: {e!r}")
            traceback.print_exc()

    print(f"\n{'='*78}\n{len(results)} benchmarks ok, {len(failures)} failed"
          + (f": {failures}" if failures else ""))
    if args.json_out:
        def clean(o):
            if isinstance(o, dict):
                return {str(k): clean(v) for k, v in o.items()}
            if isinstance(o, (list, tuple)):
                return [clean(v) for v in o]
            if hasattr(o, "item"):
                return o.item()
            return o

        with open(args.json_out, "w") as f:
            json.dump(clean(results), f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
