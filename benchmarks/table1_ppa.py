"""Table I reproduction: analytic PPA model vs every paper datapoint.

The paper's Table I gives post-synthesis area/power for serial/parallel
tuGEMM at {2,4,8}-bit × {16×16, 32×32} (45 nm, 400 MHz). Our calibrated
model (core/ppa.py) must reproduce all 12 points; this benchmark prints the
side-by-side table and the fit error, and checks the paper's scaling claims:
~2.1×/2.0× (serial) and ~1.6×/1.7× (parallel) area/power per 2× bit-width,
and ~4× area/power from 16×16 → 32×32.
"""

from __future__ import annotations

import numpy as np

from repro.core.ppa import TABLE1, ppa_model


def run(fast: bool = False) -> dict:
    rows = []
    errs = []
    print(f"\n{'config':<22} {'area paper':>10} {'area model':>10} {'err%':>6} "
          f"{'pow paper':>10} {'pow model':>10} {'err%':>6}")
    for (variant, S, w), (a_ref, p_ref) in sorted(TABLE1.items()):
        m = ppa_model(variant)
        a = m.area_mm2(w, S, S, S)
        p = m.power_w(w, S, S, S)
        ea = 100 * (a - a_ref) / a_ref
        ep = 100 * (p - p_ref) / p_ref
        errs += [abs(ea), abs(ep)]
        rows.append(dict(variant=variant, S=S, w=w, area_model=a, power_model=p,
                         area_err_pct=ea, power_err_pct=ep))
        print(f"{variant:>8} {S}x{S} w={w:<2} {a_ref:>10.3f} {a:>10.3f} {ea:>6.1f} "
              f"{p_ref:>10.3f} {p:>10.3f} {ep:>6.1f}")

    # paper scaling claims
    def ratio(variant, metric):
        vals = []
        for S in (16, 32):
            for hi, lo in ((8, 4), (4, 2)):
                a = TABLE1[(variant, S, hi)][metric] / TABLE1[(variant, S, lo)][metric]
                vals.append(a)
        return float(np.mean(vals))

    claims = {
        "serial area per 2x bits (paper 2.1x)": ratio("serial", 0),
        "serial power per 2x bits (paper 2.0x)": ratio("serial", 1),
        "parallel area per 2x bits (paper 1.6x)": ratio("parallel", 0),
        "parallel power per 2x bits (paper 1.7x)": ratio("parallel", 1),
    }
    print()
    for k, v in claims.items():
        print(f"  {k}: {v:.2f}x")
    size_scale = np.mean(
        [TABLE1[(v, 32, w)][i] / TABLE1[(v, 16, w)][i]
         for v in ("serial", "parallel") for w in (2, 4, 8) for i in (0, 1)]
    )
    print(f"  16x16 -> 32x32 area/power (paper ~4x): {size_scale:.2f}x")
    print(f"  PPA model fit: max err {max(errs):.1f}%, mean {np.mean(errs):.1f}%")
    return {"rows": rows, "max_err_pct": max(errs), "mean_err_pct": float(np.mean(errs)),
            "claims": claims, "size_scale": float(size_scale)}


if __name__ == "__main__":
    run()
