"""Fig 5 reproduction: max-value profiling of INT8 DNN inference →
average-case tuGEMM latency.

The paper tracks the maximum |value| per GEMM during INT8 ResNet18 inference
(PyTorch/ImageNet — not available offline). We reproduce the **methodology**
on two workloads (DESIGN.md §2C documented assumption):

  1. a JAX ResNet-style CNN (conv-as-im2col-GEMM so convs route through the
     int8 tuGEMM backend), briefly trained on synthetic 32×32 images so the
     activation statistics are post-training realistic rather than random;
  2. the quantized LM zoo (qwen3-0.6b smoke), int8 dynamic quantization.

Outputs the Fig 5 histogram + cumulative curve, E[max] (paper: 41 ⇒ 3.1×
below 128), and the implied average-case latency speedup (paper: ~10×).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig, get_config
from repro.core.latency import average_case_cycles, worst_case_cycles
from repro.models import forward, init
from repro.quant.qlinear import GemmBackend, dense, gemm
from repro.quant.stats import collecting


# ------------------------------------------------------- tiny ResNet in JAX
def _im2col(x: jnp.ndarray, k: int = 3, stride: int = 1) -> jnp.ndarray:
    """(B, H, W, C) -> (B*Ho*Wo, k*k*C): conv becomes a GEMM."""
    B, H, W, C = x.shape
    pad = k // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    cols = []
    for di in range(k):
        for dj in range(k):
            cols.append(xp[:, di : di + H : stride, dj : dj + W : stride, :])
    out = jnp.concatenate(cols, axis=-1)
    Ho, Wo = out.shape[1], out.shape[2]
    return out.reshape(B * Ho * Wo, k * k * C), (B, Ho, Wo)


def _conv_gemm(p, x, backend, name):
    cols, (B, Ho, Wo) = _im2col(x)
    y = gemm(cols, p["kernel"], backend=backend, name=name)
    return y.reshape(B, Ho, Wo, -1)


def resnet_init(key, width: int = 16, blocks: int = 4, classes: int = 10):
    ks = jax.random.split(key, 2 + 2 * blocks + 1)
    p = {"stem": {"kernel": jax.random.normal(ks[0], (27, width)) * 0.1}}
    for i in range(blocks):
        p[f"b{i}a"] = {"kernel": jax.random.normal(ks[1 + 2 * i], (9 * width, width)) * 0.05}
        p[f"b{i}b"] = {"kernel": jax.random.normal(ks[2 + 2 * i], (9 * width, width)) * 0.05}
    p["head"] = {"kernel": jax.random.normal(ks[-1], (width, classes)) * 0.1}
    return p


def resnet_apply(p, x, backend, blocks: int = 4):
    h = jax.nn.relu(_conv_gemm(p["stem"], x, backend, "stem"))
    for i in range(blocks):
        r = jax.nn.relu(_conv_gemm(p[f"b{i}a"], h, backend, f"b{i}a"))
        r = _conv_gemm(p[f"b{i}b"], r, backend, f"b{i}b")
        h = jax.nn.relu(h + r)                       # residual
    pooled = h.mean(axis=(1, 2))
    return gemm(pooled, p["head"]["kernel"], backend=backend, name="head")


def _train_briefly(p, key, steps: int = 30):
    """A few SGD steps on a synthetic 10-class problem (so activations are
    shaped by training, as in the paper's trained ResNet18)."""

    def batch(k):
        kx, kc = jax.random.split(k)
        cls = jax.random.randint(kc, (32,), 0, 10)
        protos = jax.random.normal(jax.random.PRNGKey(7), (10, 8, 8, 3))
        x = protos[cls] + 0.3 * jax.random.normal(kx, (32, 8, 8, 3))
        return x, cls

    bf = GemmBackend("bf16")

    @jax.jit
    def step(p, k):
        x, y = batch(k)

        def loss(p):
            logits = resnet_apply(p, x, bf)
            return -jnp.mean(
                jax.nn.log_softmax(logits)[jnp.arange(32), y]
            )

        l, g = jax.value_and_grad(loss)(p)
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g), l

    for i in range(steps):
        p, l = step(p, jax.random.fold_in(key, i))
    return p, float(l)


def run(fast: bool = False) -> dict:
    from repro.quant.calibration import calibrating, static_scales

    key = jax.random.PRNGKey(0)
    int8 = GemmBackend("int8", collect_stats=True)

    # 1) CNN workload — static PTQ: calibrate scales on one batch, profile
    # max values on others (the paper's methodology; dynamic quantization
    # would pin every max at 127 by construction)
    p = resnet_init(key)
    p, final_loss = _train_briefly(p, key, steps=10 if fast else 30)
    with calibrating() as reg:
        xc = jax.random.normal(jax.random.fold_in(key, 1), (8, 8, 8, 3)) * 2.0
        jax.block_until_ready(resnet_apply(p, xc, GemmBackend("int8")))
    with static_scales(reg), collecting(bitwidth=8) as col:
        for i in range(3 if fast else 8):
            x = jax.random.normal(jax.random.fold_in(key, 100 + i), (8, 8, 8, 3))
            jax.block_until_ready(resnet_apply(p, x, int8))
    prof_cnn = col.profile()

    # 2) LM workload, same two-pass static scheme
    cfg = get_config("qwen3-0.6b_smoke")
    rc = RunConfig(dtype="float32", param_dtype="float32", remat="none",
                   quant_policy="*=int8:stats")
    rc_cal = RunConfig(dtype="float32", param_dtype="float32", remat="none",
                       quant_policy="*=int8")
    params = init(cfg, rc, key)
    with calibrating() as reg2:
        tc = jax.random.randint(jax.random.fold_in(key, 2), (2, 32), 0, cfg.vocab_size)
        h, _, _ = forward(cfg, rc_cal, params, {"tokens": tc})
        jax.block_until_ready(h)
    with static_scales(reg2), collecting(bitwidth=8) as col2:
        for i in range(2 if fast else 4):
            toks = jax.random.randint(jax.random.fold_in(key, 200 + i), (2, 32), 0, cfg.vocab_size)
            h, _, _ = forward(cfg, rc, params, {"tokens": toks})
            jax.block_until_ready(h)
    prof_lm = col2.profile()

    out = {}
    for name, prof in (("resnet-cnn", prof_cnn), ("qwen3-lm", prof_lm)):
        em = prof.expected_max()
        cum = prof.cumulative_pct()
        le50 = float(np.searchsorted(cum, 50.0))
        le90 = float(np.searchsorted(cum, 90.0))
        sp = prof.speedup_vs_worst_case()
        wc = worst_case_cycles(8, 16, "serial")
        ac = average_case_cycles(prof, 16, "serial")
        print(f"\n[{name}] GEMM ops profiled: {prof.total}")
        print(f"  E[max] = {em:.1f} / 128  ({128/max(em,1e-9):.1f}x below max; paper: 41 => 3.1x)")
        print(f"  50% of ops have max <= {le50:.0f}; 90% <= {le90:.0f} "
              f"(paper: 50 and 80 for ResNet18)")
        print(f"  avg-case serial cycles {ac:,.0f} vs worst {wc:,} => "
              f"{sp:.1f}x faster (paper: ~10x)")
        out[name] = {"expected_max": em, "speedup": sp, "ops": prof.total,
                     "p50_max": le50, "p90_max": le90}
    # histogram (text) for the CNN profile
    print("\n  Fig5-style histogram (CNN, 8 bins):")
    counts = prof_cnn.counts
    step = (len(counts) + 7) // 8
    for b in range(8):
        lo, hi = b * step, min((b + 1) * step, len(counts))
        frac = counts[lo:hi].sum() / max(counts.sum(), 1)
        print(f"   [{lo:3d}-{hi:3d}) {'#' * int(frac * 60):<60s} {100*frac:.1f}%")
    return out


if __name__ == "__main__":
    run()
