"""Serving A/B under a bursty arrival trace: legacy dense engine vs the
chunked-prefill scheduler (dense and paged layouts).

The trace is the scenario the scheduler exists for: requests arrive in
bursts mid-run with *varied* prompt lengths. The legacy engine admits each
one as a separate B=1 prefill call — a jit cache entry per distinct prompt
length and a pool-wide decode stall per admission — while the scheduler
packs prompt chunks and decode rows into one fixed-shape step per tick
(single compile for the whole run). Reported per engine:

- decode tokens/s, cold (includes compiles — what a fresh server sees under
  unbounded prompt-length traffic) and warm (second identical trace, every
  legacy shape already compiled — isolates the head-of-line stall itself)
- cache bytes: reserved vs live high-water (paged ∝ live tokens; dense
  pins max_batch × capacity regardless of occupancy)

    PYTHONPATH=src python benchmarks/serve_bench.py          # full, writes JSON
    PYTHONPATH=src python benchmarks/serve_bench.py --fast   # CI smoke, no JSON
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs.base import RunConfig, get_config
from repro.models import init
from repro.serve import AdmissionController, Engine, Request, Scheduler

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "BENCH_serve.json")
OUT_ROBUST = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "BENCH_robust.json")
OUT_PREFIX = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "BENCH_prefix.json")


def bursty_trace(rng, *, requests, min_prompt, max_prompt, burst, gap, max_new):
    """[(arrival_step, Request)] — bursts of ``burst`` requests every
    ``gap`` engine steps, prompt lengths uniform in [min_prompt, max_prompt]."""
    trace = []
    for rid in range(requests):
        arrival = (rid // burst) * gap
        plen = int(rng.integers(min_prompt, max_prompt + 1))
        trace.append(
            (arrival, Request(rid=rid, prompt=rng.integers(0, 256, plen).tolist(),
                              max_new=max_new))
        )
    return trace


def drive(eng, trace, step_fn, max_steps=10_000):
    """Feed the trace by engine step index; returns (wall_s, steps, tokens).

    Tokens are summed over the submitted Request objects themselves (the
    legacy engine recycles slots, so its resident requests at drain time are
    only the tail of the trace)."""
    reqs = [Request(r.rid, list(r.prompt), r.max_new) for _, r in trace]
    pending = sorted(zip([a for a, _ in trace], reqs), key=lambda t: t[0])
    t0 = time.perf_counter()
    step = 0
    while step < max_steps:
        while pending and pending[0][0] <= step:
            eng.submit(pending.pop(0)[1])
        ran = step_fn()
        if not ran and not pending and not eng.queue:
            break
        step += 1
    jax.effects_barrier()
    return time.perf_counter() - t0, step, sum(len(r.out) for r in reqs)


def _row(engine, wall, steps, toks, reserved, high_water):
    return {
        "engine": engine,
        "wall_s": wall,
        "steps": steps,
        "generated_tokens": toks,
        "tokens_per_s": toks / wall if wall else 0.0,
        "cache_bytes_reserved": reserved,
        "cache_bytes_high_water": high_water,
    }


def run_legacy(cfg, rc, params, trace, *, capacity, max_batch):
    """Cold + warm passes on ONE engine — the jitted step functions live on
    the engine, so only same-object reuse actually hits the jit cache.
    ``reset()`` between passes rewinds the shared position counter (stale
    cache rows are length-masked away)."""
    from repro.serve.cache import cache_bytes

    eng = Engine(cfg, rc, params, capacity=capacity, max_batch=max_batch)
    total = cache_bytes(eng.caches)
    out = []
    for _ in range(2):
        wall, steps, toks = drive(eng, trace, eng.step)
        out.append(_row("legacy-dense", wall, steps, toks, total, total))
        eng.reset()
    return out


def run_scheduler(cfg, rc, params, trace, *, capacity, max_batch, num_pages=None):
    eng = Scheduler(cfg, rc, params, capacity=capacity, max_batch=max_batch,
                    num_pages=num_pages)
    out = []
    for _ in range(2):
        wall, steps, toks = drive(eng, trace, eng.tick)
        stats = eng.cache_stats()
        out.append(_row(f"scheduler-{rc.kv_layout}", wall, steps, toks,
                        stats["cache_bytes_reserved"],
                        stats["cache_bytes_high_water"]))
    return out


def run_overload(cfg, rc, params, *, capacity, max_batch, num_pages,
                 requests, max_new, chunk):
    """Overload scenario (DESIGN.md §10): sustained admissions at ~2x the
    engine's service rate, mixed priority classes and two tenants (one
    budget-capped), binding TTLs. The engine must keep nonzero goodput with
    ZERO engine stalls — overload is absorbed by the admission controller
    and the degradation ladder, never by the engine falling over."""
    rng = np.random.default_rng(1)
    # service rate ~ max_batch requests per (decode ticks + prefill ticks);
    # admit one request every `gap` ticks at double that rate
    avg_chunks = 2.0                      # prompts average ~2 prefill chunks
    service = max_batch / (max_new + avg_chunks)
    gap = max(1, round(1.0 / (2.0 * service)))

    pris = ["realtime", "interactive", "batch"]
    arrivals = []
    for rid in range(requests):
        plen = int(rng.integers(chunk, 3 * chunk + 1))
        r = Request(rid=rid, prompt=rng.integers(0, 256, plen).tolist(),
                    max_new=max_new)
        r.priority = pris[rid % 3]
        r.tenant = f"t{rid % 2}"
        arrivals.append((rid * gap, r))
    # tenant t1 gets ~60% of its demand — OVER_BUDGET must actually bind
    t1_demand = sum(len(r.prompt) + r.max_new for _, r in arrivals
                    if r.tenant == "t1")
    horizon = max_new + 3 * chunk
    adm = AdmissionController(
        max_queue=2 * max_batch,
        tenant_budgets={"t1": int(0.6 * t1_demand)},
        default_ttl={"realtime": 3 * horizon, "interactive": 6 * horizon,
                     "batch": 12 * horizon},
    )
    eng = Scheduler(cfg, rc, params, capacity=capacity, max_batch=max_batch,
                    num_pages=num_pages, admission=adm)
    pending = list(arrivals)
    t0 = time.perf_counter()
    step = 0
    while step < 10_000:
        while pending and pending[0][0] <= step:
            eng.submit(pending.pop(0)[1])
        ran = eng.tick()
        if not ran and not pending and not eng.queue:
            break
        step += 1
    jax.effects_barrier()
    wall = time.perf_counter() - t0

    h = eng.health()
    done = [r for _, r in arrivals if r.done]
    toks = sum(len(r.out) for r in done)
    in_deadline = h["completed"] - h["deadline_misses"]
    occ = h["ladder"]["occupancy"]
    total_occ = max(sum(occ.values()), 1)
    row = {
        "admission_gap_ticks": gap,
        "overload_factor": 2.0,
        "requests": requests,
        "wall_s": wall,
        "clock_ticks": h["clock"],
        "completed": h["completed"],
        "completed_in_deadline": in_deadline,
        "generated_tokens": toks,
        "goodput_requests_per_s": in_deadline / wall if wall else 0.0,
        "goodput_tokens_per_s": toks / wall if wall else 0.0,
        "deadline_miss_rate": h["deadline_misses"] / max(h["admitted"], 1),
        "rejections": h["rejections"],
        "preemptions": h["preemptions"],
        "stall_episodes": h["stall_episodes"],
        "engine_stalls": h["engine_stalls"],
        "ladder_transitions": len(h["ladder"]["transitions"]),
        "ladder_occupancy": {k: v / total_occ for k, v in occ.items()},
        "latency": h["latency"],
    }
    # every submitted request must have reached a terminal state
    unresolved = [r.rid for _, r in arrivals
                  if not r.done and r.rejected is None]
    row["unresolved"] = len(unresolved)
    return row


def shared_prefix_trace(rng, *, tenants, per_tenant, prefix_len, suffix_max,
                        max_new, gap):
    """Multi-tenant shared-prompt trace: every tenant's requests carry the
    same ``prefix_len``-token system prompt plus a short unique suffix. The
    first request per tenant arrives at step 0 (the warm-up that registers
    the prefix as its chunks commit); followers arrive ``gap`` steps apart —
    same-tick arrivals can never share (registration happens after chunk
    commit), so staggering is what makes the cache reachable at all."""
    trace = []
    rid = 0
    for t in range(tenants):
        system = rng.integers(0, 256, prefix_len).tolist()
        for k in range(per_tenant):
            suffix = rng.integers(0, 256, int(rng.integers(1, suffix_max + 1)))
            r = Request(rid=rid, prompt=system + suffix.tolist(), max_new=max_new)
            r.tenant = f"t{t}"
            trace.append((0 if k == 0 else k * gap, r))
            rid += 1
    return trace


def run_prefix(cfg, rc_paged, params, trace, *, capacity, max_batch, num_pages):
    """Prefix-cache A/B on the identical shared-prompt trace: cache off vs
    on. Hard-fails unless (a) greedy tokens are bit-exact across the pair,
    (b) the cache at least halves the prefill tokens actually computed, and
    (c) the live-page high-water drops — shared prompts cost one set of
    pages instead of one per request."""
    import dataclasses

    out = {}
    ref = None
    for label, enabled in [("prefix_off", False), ("prefix_on", True)]:
        rc = dataclasses.replace(rc_paged, prefix_cache=enabled)
        eng = Scheduler(cfg, rc, params, capacity=capacity,
                        max_batch=max_batch, num_pages=num_pages,
                        temperature=0.0)
        wall, steps, toks = drive(eng, trace, eng.tick)
        # drive() re-materializes the Request objects; recover them for the
        # token-identity check via the engine's completion list
        done = {r.rid: list(r.out) for r in eng.finished}
        stats = eng.cache_stats()
        out[label] = {
            "wall_s": wall,
            "steps": steps,
            "generated_tokens": toks,
            "tokens_per_s": toks / wall if wall else 0.0,
            "prefill_tokens_computed": eng.prefill_tokens_computed,
            "prefix_hits": eng.prefix_hits,
            "prefix_tokens_reused": eng.prefix_tokens_reused,
            "live_page_high_water": eng.mgr.live_high_water,
            "cache_bytes_high_water": stats["cache_bytes_high_water"],
            "cow_events": eng.mgr.cow_events,
        }
        if ref is None:
            ref = done
        elif done != ref:
            raise SystemExit("[serve_bench] prefix scenario FAILED: tokens "
                             "differ between prefix_off and prefix_on")
    off, on = out["prefix_off"], out["prefix_on"]
    out["prefill_reduction"] = (off["prefill_tokens_computed"]
                                / max(on["prefill_tokens_computed"], 1))
    if on["prefill_tokens_computed"] * 2 > off["prefill_tokens_computed"]:
        raise SystemExit("[serve_bench] prefix scenario FAILED: expected "
                         ">=2x prefill-token reduction, got "
                         f"{out['prefill_reduction']:.2f}x")
    if on["live_page_high_water"] >= off["live_page_high_water"]:
        raise SystemExit("[serve_bench] prefix scenario FAILED: live-page "
                         f"high-water did not drop "
                         f"({on['live_page_high_water']} >= "
                         f"{off['live_page_high_water']})")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b_smoke")
    ap.add_argument("--fast", action="store_true", help="CI smoke: tiny trace, no JSON")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--kv", default="int8", choices=["bfloat16", "int8"])
    args = ap.parse_args(argv)

    if args.fast:
        args.requests, args.max_new, args.capacity = 5, 4, 64

    cfg = get_config(args.arch)
    base = dict(dtype="float32", param_dtype="float32", remat="none",
                kv_cache_dtype=args.kv, block_size=args.block_size,
                prefill_chunk=args.prefill_chunk)
    rc_dense = RunConfig(**base)
    rc_paged = RunConfig(**base, kv_layout="paged")
    params = init(cfg, rc_dense, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    trace = bursty_trace(
        rng, requests=args.requests, min_prompt=args.prefill_chunk,
        max_prompt=min(args.capacity - args.max_new - 2, 4 * args.prefill_chunk),
        burst=max(args.max_batch // 2, 1), gap=3, max_new=args.max_new,
    )
    kw = dict(capacity=args.capacity, max_batch=args.max_batch)
    # paged pool sized at half the dense equivalent: enough for the trace's
    # live tokens, impossible for a dense layout (which pins the worst case)
    from repro.serve.cache import num_pages_for

    pool = num_pages_for(args.capacity, args.block_size, args.max_batch) // 2

    rows = {}
    for label, fn in [
        ("legacy_dense", lambda: run_legacy(cfg, rc_dense, params, trace, **kw)),
        ("scheduler_dense", lambda: run_scheduler(cfg, rc_dense, params, trace, **kw)),
        ("scheduler_paged", lambda: run_scheduler(cfg, rc_paged, params, trace,
                                                  num_pages=pool, **kw)),
    ]:
        cold, warm = fn()  # one engine, trace twice: pass 2 hits the jit cache
        rows[label] = {"cold": cold, "warm": warm}
        print(f"[serve_bench] {label:16s} cold {cold['tokens_per_s']:8.2f} tok/s  "
              f"warm {warm['tokens_per_s']:8.2f} tok/s  "
              f"cache hw {cold['cache_bytes_high_water']:>9d}B "
              f"/ {cold['cache_bytes_reserved']}B reserved")

    speedup_cold = (rows["scheduler_paged"]["cold"]["tokens_per_s"]
                    / max(rows["legacy_dense"]["cold"]["tokens_per_s"], 1e-9))
    speedup_warm = (rows["scheduler_paged"]["warm"]["tokens_per_s"]
                    / max(rows["legacy_dense"]["warm"]["tokens_per_s"], 1e-9))
    # memory: paged live high-water vs the dense pool at the SAME nominal
    # capacity (scheduler_dense row; the legacy engine's pool is larger
    # still — its shared position counter needs multi-trace headroom)
    mem_ratio = (rows["scheduler_paged"]["cold"]["cache_bytes_high_water"]
                 / max(rows["scheduler_dense"]["cold"]["cache_bytes_reserved"], 1))
    print(f"[serve_bench] paged-vs-legacy speedup: {speedup_cold:.2f}x cold, "
          f"{speedup_warm:.2f}x warm; live cache = {mem_ratio:.2f}x of dense pool")

    # ---- shared-prefix scenario: multi-tenant system prompts, cache A/B
    prefix_trace = shared_prefix_trace(
        np.random.default_rng(2),
        tenants=2,
        per_tenant=3 if args.fast else 4,
        prefix_len=3 * args.prefill_chunk,
        suffix_max=max(args.block_size // 2, 2),
        max_new=args.max_new,
        gap=4,
    )
    prefix = run_prefix(cfg, rc_paged, params, prefix_trace,
                        capacity=args.capacity, max_batch=args.max_batch,
                        num_pages=2 * pool)
    print(f"[serve_bench] prefix cache: "
          f"{prefix['prefill_reduction']:.2f}x fewer prefill tokens "
          f"({prefix['prefix_off']['prefill_tokens_computed']} -> "
          f"{prefix['prefix_on']['prefill_tokens_computed']}), "
          f"live pages hw {prefix['prefix_off']['live_page_high_water']} -> "
          f"{prefix['prefix_on']['live_page_high_water']}, "
          f"{prefix['prefix_on']['prefix_hits']} hits / "
          f"{prefix['prefix_on']['prefix_tokens_reused']} tokens reused "
          f"(bit-exact)")
    if not args.fast:
        pj = {
            "arch": args.arch,
            "scenario": {"tenants": 2, "per_tenant": 4,
                         "prefix_len": 3 * args.prefill_chunk,
                         "max_batch": args.max_batch,
                         "capacity": args.capacity, "max_new": args.max_new,
                         "block_size": args.block_size,
                         "prefill_chunk": args.prefill_chunk,
                         "pool_pages": 2 * pool},
            "prefix": prefix,
        }
        with open(OUT_PREFIX, "w") as f:
            json.dump(pj, f, indent=1)
        print(f"[serve_bench] wrote {OUT_PREFIX}")

    # ---- overload scenario: 2x sustained admission rate, paged layout
    overload = run_overload(
        cfg, rc_paged, params, capacity=args.capacity,
        max_batch=args.max_batch, num_pages=pool,
        requests=3 * args.requests, max_new=args.max_new,
        chunk=args.prefill_chunk,
    )
    print(f"[serve_bench] overload 2x: goodput "
          f"{overload['goodput_requests_per_s']:.2f} req/s "
          f"({overload['goodput_tokens_per_s']:.1f} tok/s), "
          f"miss rate {overload['deadline_miss_rate']:.2f}, "
          f"rejections {overload['rejections']}, "
          f"engine_stalls {overload['engine_stalls']}, "
          f"unresolved {overload['unresolved']}")
    lat = overload["latency"]
    print("[serve_bench] overload latency (s):")
    print(f"    {'metric':8s} {'p50':>9s} {'p95':>9s} {'p99':>9s} {'n':>5s}")
    for name, key in [("ttft", "ttft_s"), ("itl", "itl_s"), ("tick", "tick_s")]:
        row = lat[key]
        print(f"    {name:8s} {row['p50']:9.4f} {row['p95']:9.4f} "
              f"{row['p99']:9.4f} {row['count']:5d}")
    if overload["engine_stalls"] or overload["unresolved"]:
        raise SystemExit("[serve_bench] overload scenario FAILED: engine "
                         "stalled or requests left unresolved")
    if not args.fast:
        robust = {
            "arch": args.arch,
            "scenario": {"max_batch": args.max_batch,
                         "capacity": args.capacity, "max_new": args.max_new,
                         "prefill_chunk": args.prefill_chunk,
                         "pool_pages": pool},
            "overload": overload,
        }
        with open(OUT_ROBUST, "w") as f:
            json.dump(robust, f, indent=1)
        print(f"[serve_bench] wrote {OUT_ROBUST}")

    if not args.fast:
        out = {
            "arch": args.arch,
            "trace": {"requests": args.requests, "max_batch": args.max_batch,
                      "capacity": args.capacity, "max_new": args.max_new,
                      "kv_dtype": args.kv, "block_size": args.block_size,
                      "prefill_chunk": args.prefill_chunk, "pool_pages": pool},
            "engines": rows,
            "speedup_paged_vs_legacy_cold": speedup_cold,
            "speedup_paged_vs_legacy_warm": speedup_warm,
            "live_cache_fraction_of_dense": mem_ratio,
        }
        with open(OUT, "w") as f:
            json.dump(out, f, indent=1)
        print(f"[serve_bench] wrote {OUT}")
    return rows


def run(fast: bool = False):
    """benchmarks.run entry point (aggregated into the harness JSON)."""
    return main(["--fast"] if fast else [])


if __name__ == "__main__":
    main()
