"""Emit EXPERIMENTS.md markdown tables from dry-run JSON artifacts."""

from __future__ import annotations

import glob
import json
import os
import sys


def rows(out_dir):
    out = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def table(out_dir, mesh_filter=None):
    lines = [
        "| arch × shape | mesh | compute ms | memory ms | collective ms | dominant | useful | RL% | peak GB/chip | fits |",
        "|---|---|---:|---:|---:|---|---:|---:|---:|---|",
    ]
    for d in sorted(rows(out_dir), key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if mesh_filter and d["mesh"] != mesh_filter:
            continue
        fits = "✓" if d["peak_bytes_per_chip"] <= 16e9 else "✗"
        lines.append(
            f"| {d['arch']} × {d['shape']} | {d['mesh']} | {d['compute_s']*1e3:.1f} | "
            f"{d['memory_s']*1e3:.1f} | {d['collective_s']*1e3:.1f} | {d['dominant']} | "
            f"{d['useful_ratio']:.2f} | {d['mfu']*100:.2f} | "
            f"{d['peak_bytes_per_chip']/1e9:.2f} | {fits} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    mesh = sys.argv[2] if len(sys.argv) > 2 else None
    print(table(d, mesh))
