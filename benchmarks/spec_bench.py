"""Speculative decoding A/B: int2-draft + batched-verify vs the plain
chunked-prefill scheduler (the PR 4 baseline path).

Greedy speculative decode emits the same token sequences as the baseline
(tests/test_spec.py pins that bit-for-bit), so this bench isolates the
*engine* deltas on an identical workload:

- acceptance rate (how often the near-free int2 draft matches the int8
  target — the lever that converts serial decode ticks into batched verify)
- decode ticks per generated token (step compression: the decode critical
  path the paper's serial unary unit actually walks) and wall tokens/s
- energy per accepted token on the modeled 16×16 unit, split draft-int2 vs
  verify-int8, *including* rejected-draft and rejected-verify waste —
  Table I's PPA slope is what makes the draft side ~free

    PYTHONPATH=src python benchmarks/spec_bench.py          # full, writes JSON
    PYTHONPATH=src python benchmarks/spec_bench.py --fast   # CI smoke, no JSON
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.configs.base import RunConfig, get_config
from repro.models import init
from repro.serve import Request, Scheduler

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "BENCH_spec.json")


def _drive(cfg, rc, params, prompts, *, capacity, max_batch, max_new):
    eng = Scheduler(cfg, rc, params, capacity=capacity, max_batch=max_batch,
                    track_energy=True)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid=rid, prompt=list(p), max_new=max_new))
    t0 = time.perf_counter()
    done = eng.run()
    jax.effects_barrier()
    wall = time.perf_counter() - t0
    return eng, done, wall


def _row(eng, done, wall):
    s = eng.spec_summary()
    gen = s["generated_tokens"]
    return {
        "generated_tokens": gen,
        "ticks": eng.ticks,
        "ticks_per_token": eng.ticks / max(gen, 1),
        "wall_s": wall,
        "tokens_per_s": gen / wall if wall else 0.0,
        "drafted_tokens": s["drafted_tokens"],
        "accepted_draft_tokens": s["accepted_draft_tokens"],
        "acceptance_rate": s["acceptance_rate"],
        "energy_j": s["energy_j"],
        "draft_energy_j": s["draft_energy_j"],
        "target_energy_j": s["target_energy_j"],
        "wasted_draft_energy_j": s["wasted_draft_energy_j"],
        "unit_latency_s": s["latency_s"],
        "energy_per_accepted_token_j": s["energy_per_accepted_token_j"],
    }


def run(fast: bool = False, *, arch="qwen3-0.6b_smoke", gammas=(2, 4)):
    requests, max_new, capacity, max_batch = 8, 16, 128, 4
    if fast:
        requests, max_new, capacity, gammas = 4, 6, 64, (2,)

    cfg = get_config(arch)
    base = RunConfig(
        dtype="float32", param_dtype="float32", remat="none",
        kv_cache_dtype="int8", kv_layout="paged", block_size=8,
        prefill_chunk=8, quant_policy="*=int8",
    )
    params = init(cfg, base, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 17))).tolist()
               for _ in range(requests)]
    kw = dict(capacity=capacity, max_batch=max_batch, max_new=max_new)

    eng, done, wall = _drive(cfg, base, params, prompts, **kw)
    base_seqs = {r.rid: list(r.out) for r in done}
    rows = {"baseline": _row(eng, done, wall)}
    rows["baseline"]["spec_gamma"] = 0
    print(f"[spec_bench] baseline        : "
          f"{rows['baseline']['tokens_per_s']:8.2f} tok/s  "
          f"{rows['baseline']['ticks_per_token']:.2f} ticks/tok  "
          f"{rows['baseline']['energy_per_accepted_token_j']*1e6:8.3f} uJ/tok")

    for gamma in gammas:
        rc = dataclasses.replace(base, spec_gamma=gamma, draft_policy="*=int2")
        eng, done, wall = _drive(cfg, rc, params, prompts, **kw)
        assert {r.rid: list(r.out) for r in done} == base_seqs, (
            "greedy speculative decode diverged from the baseline sequences"
        )
        r = _row(eng, done, wall)
        r["spec_gamma"] = gamma
        r["vs_baseline"] = {
            "tick_compression": (rows["baseline"]["ticks_per_token"]
                                 / max(r["ticks_per_token"], 1e-12)),
            "wall_speedup": (r["tokens_per_s"]
                             / max(rows["baseline"]["tokens_per_s"], 1e-12)),
            "energy_overhead": (r["energy_per_accepted_token_j"]
                                / max(rows["baseline"]["energy_per_accepted_token_j"],
                                      1e-30)),
            "draft_energy_fraction": r["draft_energy_j"] / max(r["energy_j"], 1e-30),
        }
        rows[f"spec_gamma{gamma}"] = r
        print(f"[spec_bench] spec gamma={gamma}    : "
              f"{r['tokens_per_s']:8.2f} tok/s  "
              f"{r['ticks_per_token']:.2f} ticks/tok  "
              f"{r['energy_per_accepted_token_j']*1e6:8.3f} uJ/tok  "
              f"accept {r['acceptance_rate']:.2f}  "
              f"draft {100*r['vs_baseline']['draft_energy_fraction']:.2f}% of E")

    out = {
        "arch": arch,
        "note": "random-init smoke weights decode into near-constant greedy "
                "sequences, so the acceptance rate here is an upper bound; "
                "the energy split and tick compression are the load-bearing "
                "numbers",
        "policy": {"target": "*=int8", "draft": "*=int2"},
        "trace": {"requests": requests, "max_new": max_new,
                  "capacity": capacity, "max_batch": max_batch},
        "engines": rows,
    }
    if not fast:
        with open(OUT, "w") as f:
            json.dump(out, f, indent=1)
        print(f"[spec_bench] wrote {OUT}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b_smoke")
    ap.add_argument("--fast", action="store_true", help="CI smoke: tiny trace, no JSON")
    args = ap.parse_args(argv)
    return run(fast=args.fast, arch=args.arch)


if __name__ == "__main__":
    main()
