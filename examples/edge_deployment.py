"""Edge deployment study (the paper's §IV direction, end to end):

1. quantize a small LM to int8/int4/int2 through the framework's PTQ path,
2. profile its GEMM max-value statistics on real forward passes (Fig 5
   methodology, static scales),
3. plan the whole workload onto tuGEMM tile arrays (serial/parallel ×
   bitwidth) and report area/power/latency/energy per generated token,
4. compare accuracy proxies (logit fidelity) across bitwidths — the
   exactness story: tuGEMM int8 matches the float model's argmax almost
   everywhere, and *every* arithmetic error is a quantization error, never
   a stochastic one.

    PYTHONPATH=src python examples/edge_deployment.py
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig, get_config
from repro.core.tiling import GemmTask, TileConfig, plan_workload
from repro.models import forward, init
from repro.quant.calibration import calibrating, static_scales
from repro.quant.stats import collecting


def main():
    key = jax.random.PRNGKey(0)
    cfg = get_config("qwen3-0.6b_smoke")
    rc_f = RunConfig(dtype="float32", param_dtype="float32", remat="none")
    params = init(cfg, rc_f, key)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (4, 32), 0, cfg.vocab_size)

    h_ref, _, _ = forward(cfg, rc_f, params, {"tokens": toks})

    # 1+2) quantized forwards + Fig5 profiling (static scales)
    profs, agreements = {}, {}
    for bits in (8, 4, 2):
        rc_q = RunConfig(dtype="float32", param_dtype="float32", remat="none",
                         quant_policy=f"*=int{bits}:stats")
        rc_cal = RunConfig(dtype="float32", param_dtype="float32", remat="none",
                           quant_policy=f"*=int{bits}")
        with calibrating() as reg:
            hc, _, _ = forward(cfg, rc_cal, params,
                               {"tokens": jax.random.randint(jax.random.fold_in(key, 2), (4, 32), 0, cfg.vocab_size)})
            jax.block_until_ready(hc)
        with static_scales(reg), collecting(bitwidth=bits) as col:
            h_q, _, _ = forward(cfg, rc_q, params, {"tokens": toks})
            jax.block_until_ready(h_q)
        profs[bits] = col
        cos = float(
            (h_ref * h_q).sum()
            / jnp.maximum(jnp.linalg.norm(h_ref) * jnp.linalg.norm(h_q), 1e-9)
        )
        agreements[bits] = cos
        prof = col.profile()
        print(f"int{bits}: hidden-state cosine vs float = {cos:.4f} | "
              f"{len(col.records)} GEMMs, E[max]={prof.expected_max():.1f}, "
              f"avg-case speedup {prof.speedup_vs_worst_case():.1f}x")

    # 3) map the full-size model's decode workload onto tuGEMM arrays
    full = get_config("qwen3-0.6b")
    d, hd, h, kv, ff, L = (full.d_model, full.resolved_head_dim, full.num_heads,
                           full.num_kv_heads, full.d_ff, full.num_layers)
    tasks = [
        GemmTask("qkv+o", 1, d, (h + 2 * kv) * hd + h * hd, count=L),
        GemmTask("mlp", 1, d, 2 * ff, count=L),
        GemmTask("mlp_down", 1, ff, d, count=L),
        GemmTask("lm_head", 1, d, full.vocab_size, count=1),
    ]
    prof8 = profs[8].profile()
    print(f"\n{full.name} single-token decode on tuGEMM arrays "
          f"(avg-case cycles from the measured profile):")
    print(f"{'config':<30} {'area mm²':>9} {'power W':>8} {'ms/token':>9} {'mJ/token':>9}")
    for variant in ("serial", "parallel"):
        for bits in (8, 4, 2):
            rep = plan_workload(tasks, TileConfig(variant=variant, S=16, bitwidth=bits, units=64),
                                profile=prof8)
            print(f"{f'{variant} {bits}-bit 64x16x16 units':<30} {rep.area_mm2:>9.3f} "
                  f"{rep.power_w:>8.3f} {rep.latency_s*1e3:>9.1f} {rep.energy_j*1e3:>9.2f}")

    assert agreements[8] > 0.99, "int8 tuGEMM must track the float model closely"
    assert agreements[8] > agreements[2], "lower bits => more quantization error"
    print("\n[edge_deployment] OK")


if __name__ == "__main__":
    main()
