"""Quickstart: the tuGEMM core in five minutes.

Runs the paper's contribution end to end on CPU:
 1. exact temporal-unary GEMM (serial/parallel cycle counts + exactness)
 2. the gate-level cycle-accurate simulator agreeing with the analytic model
 3. PPA of the hardware design points (Table I)
 4. a quantized LM forward pass routed through the tuGEMM int8 backend,
    collecting the hardware statistics the paper profiles in Fig 5.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig, get_config
from repro.core import (
    evaluate_ppa,
    tugemm,
    worst_case_cycles,
)
from repro.core.cycle_sim import simulate_serial
from repro.models import forward, init
from repro.quant.stats import collecting


def main():
    rng = np.random.default_rng(0)

    # 1) exact temporal-unary GEMM ------------------------------------------
    A = rng.integers(-8, 8, size=(16, 16))     # 4-bit operands
    B = rng.integers(-8, 8, size=(16, 16))
    C = rng.integers(-8, 8, size=(16, 16))
    Y, stats = tugemm(A, B, C)
    assert (np.asarray(Y) == A @ B + C).all(), "tuGEMM must be EXACT"
    print(f"1. tuGEMM 16x16 (4-bit): exact ✓   serial={int(stats.serial_cycles):,} cycles, "
          f"parallel={int(stats.parallel_cycles):,} cycles "
          f"(worst case {worst_case_cycles(4, 16, 'serial'):,} / "
          f"{worst_case_cycles(4, 16, 'parallel'):,})")

    # 2) cycle-accurate golden model ----------------------------------------
    sim = simulate_serial(A, B, C)
    assert (sim.Y == np.asarray(Y)).all()
    assert sim.total_cycles == int(stats.serial_cycles), (sim.total_cycles, int(stats.serial_cycles))
    print(f"2. gate-level simulator: output + cycle count agree with the analytic op ✓")

    # 3) PPA (Table I design points) ----------------------------------------
    for variant in ("serial", "parallel"):
        rep = evaluate_ppa(variant, 4, 16, 16, 16, float(stats.serial_cycles if variant == "serial" else stats.parallel_cycles))
        print(f"3. {variant:8s} 4-bit 16x16: {rep.area_mm2*1e3:.1f} mm²·10⁻³  "
              f"{rep.power_w*1e3:.1f} mW  {rep.latency_s*1e6:.2f} µs  {rep.energy_j*1e9:.1f} nJ")

    # 4) a real model through the tuGEMM backend ----------------------------
    cfg = get_config("qwen3-0.6b_smoke")
    rc = RunConfig(dtype="float32", param_dtype="float32", remat="none",
                   quant_policy="*=int8:stats")
    params = init(cfg, rc, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    with collecting(bitwidth=8) as col:
        h, _, _ = forward(cfg, rc, params, {"tokens": toks})
        jax.block_until_ready(h)
    prof = col.profile()
    print(f"4. qwen3-0.6b (smoke) int8 forward: {len(col.records)} GEMMs through the "
          f"tuGEMM backend, E[max|value|]={prof.expected_max():.0f}, "
          f"total serial cycles {col.total_cycles('serial'):,} "
          f"(avg-case speedup vs worst {prof.speedup_vs_worst_case():.1f}x)")
    print("\nquickstart OK")


if __name__ == "__main__":
    main()
