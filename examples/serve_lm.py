"""Serving example: continuous batching with slot reuse, int8 KV cache and
the int8 tuGEMM weight path (prequantized weights = the paper's deployment
mode: exact low-precision GEMM serving).

    PYTHONPATH=src python examples/serve_lm.py
    PYTHONPATH=src python examples/serve_lm.py --gemm-backend int8 --kv int8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import RunConfig, get_config
from repro.models import init
from repro.serve import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b_smoke")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--kv", default="bfloat16", choices=["bfloat16", "int8"])
    ap.add_argument("--gemm-backend", default="bf16", choices=["bf16", "int8", "int4", "int2"])
    ap.add_argument("--temperature", type=float, default=0.7)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    rc = RunConfig(dtype="float32", param_dtype="float32", remat="none",
                   kv_cache_dtype=args.kv,
                   quant_policy=f"*={args.gemm_backend}")
    params = init(cfg, rc, jax.random.PRNGKey(0))

    eng = Engine(cfg, rc, params, capacity=64, max_batch=args.max_batch,
                 temperature=args.temperature)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab_size, 8).tolist(),
                           max_new=args.max_new))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[serve_lm] {args.requests} requests over {args.max_batch} slots "
          f"(continuous batching): {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s, kv={args.kv}, gemm={args.gemm_backend})")
    for r in done:
        print(f"  req {r.rid}: {len(r.out)} tokens {r.out[:6]}...")
    assert all(len(r.out) >= args.max_new for r in done)
    print("[serve_lm] OK")


if __name__ == "__main__":
    main()
