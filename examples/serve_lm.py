"""Serving example: the chunked-prefill scheduler with a paged int8 KV cache
and the int8 tuGEMM weight path (prequantized weights = the paper's
deployment mode: exact low-precision GEMM serving).

    PYTHONPATH=src python examples/serve_lm.py
    PYTHONPATH=src python examples/serve_lm.py --kv-layout paged --block-size 8
    PYTHONPATH=src python examples/serve_lm.py --gemm-backend int8 --kv int8 \
        --kv-layout paged --engine scheduler
    PYTHONPATH=src python examples/serve_lm.py --gemm-backend int8 \
        --spec-gamma 2 --draft-policy "*=int2"   # speculative int2 drafting

``--engine legacy`` runs the old dense-slot engine (one-shot B=1 prefill)
for comparison — watch the tok/s gap when prompts vary in length.
``--spec-gamma N`` turns on speculative decoding: each slot drafts N tokens
per tick against the near-free int2 view of the same weights and the target
verifies them in one batched mixed step (DESIGN.md §9; default off — off-path
behavior is identical to the plain scheduler).

Multi-device serving (DESIGN.md §12) lives on the full launcher — the same
scheduler, shard_map-ped over a dp×tp mesh with quantize-before-all-gather
collectives. ``--devices N`` forces N host-platform CPU devices (must be
the first thing jax sees, which is why the launcher scans argv before
importing jax) and ``--mesh dp,tp`` shards the step:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b_smoke \
        --devices 8 --mesh 2,4 --kv-layout paged --kv-dtype int8 \
        --policy 'attn.*=int8,mlp.*=int2,*=bf16' --energy

dp shards batch rows, tp shards attention head groups / dense-FFN columns /
MoE experts. Greedy tokens are bit-identical to the single-device run; the
exit summary prints wire bytes by bitwidth and MoE capacity drops.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import RunConfig, get_config
from repro.models import init
from repro.serve import Engine, Request, Scheduler, install_sigint_drain


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b_smoke")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--engine", default="scheduler", choices=["scheduler", "legacy"])
    ap.add_argument("--kv-layout", default="paged", choices=["dense", "paged"])
    ap.add_argument("--block-size", type=int, default=8, help="tokens per KV page")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share block-aligned prompt prefixes via ref-counted "
                         "copy-on-write pages (paged scheduler only)")
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--kv", default="bfloat16", choices=["bfloat16", "int8"])
    ap.add_argument("--gemm-backend", default="bf16", choices=["bf16", "int8", "int4", "int2"])
    ap.add_argument("--spec-gamma", type=int, default=0,
                    help="speculative decoding: draft N tokens/tick at the "
                         "draft policy and batch-verify (0 = off)")
    ap.add_argument("--draft-policy", default="*=int2",
                    help="QuantPolicy for the draft pass (with --spec-gamma)")
    ap.add_argument("--temperature", type=float, default=0.7)
    args = ap.parse_args(argv)

    spec_on = args.spec_gamma > 0 and args.engine == "scheduler"
    cfg = get_config(args.arch)
    rc = RunConfig(dtype="float32", param_dtype="float32", remat="none",
                   kv_cache_dtype=args.kv,
                   kv_layout=args.kv_layout if args.engine == "scheduler" else "dense",
                   block_size=args.block_size, prefill_chunk=args.prefill_chunk,
                   prefix_cache=(args.prefix_cache and args.kv_layout == "paged"
                                 and args.engine == "scheduler"),
                   quant_policy=f"*={args.gemm_backend}",
                   spec_gamma=args.spec_gamma if spec_on else 0,
                   draft_policy=args.draft_policy if spec_on else None)
    params = init(cfg, rc, jax.random.PRNGKey(0))

    if args.engine == "scheduler":
        eng = Scheduler(cfg, rc, params, capacity=64, max_batch=args.max_batch,
                        temperature=args.temperature)
    else:
        eng = Engine(cfg, rc, params, capacity=64, max_batch=args.max_batch,
                     temperature=args.temperature)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=rid, prompt=rng.integers(0, cfg.vocab_size, 8).tolist(),
                    max_new=args.max_new)
            for rid in range(args.requests)]
    for r in reqs:
        eng.submit(r)
    # graceful shutdown: ^C drains active slots (partial outputs and energy
    # meters survive), a second ^C aborts hard
    restore = install_sigint_drain(eng) if args.engine == "scheduler" else None
    t0 = time.perf_counter()
    try:
        eng.run()
    finally:
        if restore is not None:
            restore()
    dt = time.perf_counter() - t0
    # count over the submitted requests — the legacy engine's run() returns
    # only the slot residents, a fraction of the trace
    done = reqs
    toks = sum(len(r.out) for r in done)
    print(f"[serve_lm] {args.requests} requests over {args.max_batch} slots "
          f"({args.engine}, kv_layout={rc.kv_layout}): {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s, kv={args.kv}, gemm={args.gemm_backend})")
    if args.engine == "scheduler":
        stats = eng.cache_stats()
        print(f"[serve_lm] cache: {stats['cache_bytes_high_water']}B live high-water "
              f"of {stats['cache_bytes_reserved']}B reserved")
        if rc.prefix_cache:
            print(f"[serve_lm] prefix: {eng.prefix_hits} hits, "
                  f"{eng.prefix_tokens_reused} prompt tokens reused, "
                  f"{eng.prefill_tokens_computed} prefilled")
        if spec_on:
            s = eng.spec_summary()
            print(f"[serve_lm] spec: gamma={s['spec_gamma']} "
                  f"draft={s['draft_policy']} "
                  f"acceptance={s['acceptance_rate']:.2f} "
                  f"({s['accepted_draft_tokens']}/{s['drafted_tokens']} drafts)")
    for r in done:
        print(f"  req {r.rid}: {len(r.out)} tokens {r.out[:6]}...")
    if args.engine == "scheduler" and eng.draining:
        h = eng.health()
        print(f"[serve_lm] drained: completed={h['completed']} "
              f"rejections={h['rejections']} (partial outputs kept)")
    else:
        assert all(len(r.out) >= args.max_new for r in done)
        print("[serve_lm] OK")


if __name__ == "__main__":
    main()
