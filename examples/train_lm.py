"""End-to-end training driver: a ~100M-param LM trained for a few hundred
steps on the synthetic Markov corpus, with checkpointing, auto-resume and
the straggler watchdog — the full production loop at CPU-feasible scale.

    PYTHONPATH=src python examples/train_lm.py                # ~300 steps
    PYTHONPATH=src python examples/train_lm.py --steps 50     # quicker
    PYTHONPATH=src python examples/train_lm.py --arch qwen3-0.6b --seq-len 128

Default arch is a ~100M-param reduction of smollm (same family/topology,
fewer layers and narrower) so a few hundred steps finish on CPU. Loss must
drop well below the unigram entropy of the synthetic corpus — that is
asserted at the end.
"""

from __future__ import annotations

import argparse
import os
import tempfile

import numpy as np

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig, register
from repro.data import make_batches
from repro.models.model import count_params
from repro.train import Trainer

# ~100M params: 12L × d512 (+ 49k vocab embedding ≈ 25M + body ≈ 40M…100M range)
LM100M = register(
    ModelConfig(
        name="lm-100m",
        family="dense",
        num_layers=12,
        d_model=512,
        num_heads=8,
        num_kv_heads=4,
        d_ff=1536,
        vocab_size=49152,
        head_dim=64,
        attn_type="gqa",
        rope_theta=1e4,
        tie_embeddings=True,
    )
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm-100m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--moments", default="float32", choices=["float32", "int8"])
    args = ap.parse_args(argv)

    from repro.configs.base import get_config

    cfg = get_config(args.arch)
    rc = RunConfig(
        dtype="float32", param_dtype="float32", remat="none",
        lr=args.lr, warmup_steps=max(5, args.steps // 20), total_steps=args.steps,
        moments_dtype=args.moments,
    )
    shape = ShapeConfig("train", args.seq_len, args.batch, "train")
    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(), "repro_train_lm")

    print(f"[train_lm] {cfg.name}: {count_params(cfg)/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq_len}")
    trainer = Trainer(cfg, rc, ckpt_dir=ckpt_dir, ckpt_every=100, log_every=20)
    batches = make_batches(cfg, shape, seed=0, start_step=trainer.step)
    try:
        hist = trainer.run(batches, args.steps - trainer.step)
    finally:
        batches.close()

    if hist:
        first = np.mean([h["loss"] for h in hist[:10]])
        last = np.mean([h["loss"] for h in hist[-10:]])
        print(f"[train_lm] loss {first:.3f} -> {last:.3f} | watchdog {trainer.clock.summary()}")
        assert last < first, "loss did not decrease"
    print("[train_lm] OK")


if __name__ == "__main__":
    main()
